"""Quickstart: the Async-fork snapshot substrate in 40 lines.

Takes a consistent point-in-time snapshot of live JAX state while the
"engine" keeps destroying (donating) buffers — the exact hazard that makes
naive snapshots either blocking or inconsistent.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import AsyncForkSnapshotter, BlockingSnapshotter, PyTreeProvider

# the engine's in-memory state: any pytree of arrays
state = {
    "table": jnp.arange(512 * 1024, dtype=jnp.float32).reshape(512, 1024),
    "meta": jnp.ones((64, 64), jnp.float32),
}
provider = PyTreeProvider(state)
t0_table = np.asarray(provider.leaf(1)).copy()  # ground truth at fork time

# ---- Async-fork: O(metadata) fork + background copiers ----------------- #
snapper = AsyncForkSnapshotter(provider, block_bytes=64 << 10, copier_threads=4)
snap = snapper.fork()
print(f"fork() returned in {snap.metrics.fork_s*1e3:.2f} ms "
      f"({snap.table.n_blocks} blocks protected)")

# engine keeps serving: donated writes that DESTROY the old buffers.
for step in range(16):
    rows = list(range(step * 8, step * 8 + 8))
    snapper.before_write(1, rows)          # proactive synchronization (§4.2)
    old = provider.leaf(1)
    provider.update_leaf(1, old.at[np.asarray(rows)].set(-1.0), delete_old=True)

snap.wait()
tree = snap.to_tree()
assert np.array_equal(np.asarray(tree["table"]), t0_table), "snapshot drifted!"
print(f"snapshot consistent: child copied {snap.metrics.copied_blocks_child} "
      f"blocks, parent proactively copied {snap.metrics.copied_blocks_parent}, "
      f"{snap.metrics.n_interruptions} interruptions "
      f"({snap.metrics.out_of_service_s*1e3:.2f} ms out-of-service)")

# ---- versus default fork (blocking) ------------------------------------ #
provider2 = PyTreeProvider({"table": jnp.ones((512, 1024), jnp.float32)})
blocking = BlockingSnapshotter(provider2, block_bytes=64 << 10)
s2 = blocking.fork()
print(f"default fork blocked the engine for {s2.metrics.fork_s*1e3:.2f} ms "
      f"(vs {snap.metrics.fork_s*1e3:.2f} ms async)")
