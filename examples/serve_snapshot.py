"""Serving-path snapshot: replicate a live inference server's state
(params + KV cache) without stalling decode — the paper's FlurryDB
use case (fork-based replica creation) on the serving loop.

A decode loop generates tokens with a KV cache; mid-generation we fork a
snapshot of (params, cache) for a new replica, while decode keeps donating
the cache every step. The snapshot is bit-identical to the fork-time state.

Run:  PYTHONPATH=src python examples/serve_snapshot.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import AsyncForkSnapshotter, PyTreeProvider
from repro.models import build_model


def main():
    cfg = dataclasses.replace(
        get_config("phi3-mini-3.8b"),
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=8, head_dim=32,
        d_ff=512, vocab=4096,
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S_max = 4, 128
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, 16), 0, cfg.vocab)
    logits, cache = model.prefill(params, prompt, cache_len=S_max)

    decode = jax.jit(
        lambda p, c, t, pos: model.decode_step(p, c, t, pos),
        donate_argnums=(1,),
    )

    # snapshot provider over the live serving state
    state = {"params": params, "cache": cache}
    provider = PyTreeProvider(state)
    snapper = AsyncForkSnapshotter(provider, block_bytes=1 << 20,
                                   copier_threads=2)

    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    pos = jnp.full((B,), 16, jnp.int32)
    snap = None
    gen = [tok]
    for step in range(32):
        if step == 4:
            t0 = time.perf_counter()
            snap = snapper.fork()
            print(f"replica fork at step 4: {(time.perf_counter()-t0)*1e3:.2f} ms "
                  f"({snap.table.n_blocks} blocks)")
        if snap is not None and not snap.copy_done.is_set():
            # cache leaves are donated by decode: proactive-sync them
            for h in snap.table.leaf_handles:
                if h.path.startswith("cache"):
                    snap.complete_leaf(h.leaf_id)
        # rebind live cache leaves after the donated step
        old_cache = state["cache"]
        logits, new_cache = decode(state["params"], old_cache, tok, pos)
        state["cache"] = new_cache
        provider.refresh(state)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        pos = pos + 1
        gen.append(tok)

    snap.wait(30)
    replica = snap.to_tree()
    n_leaves = len(jax.tree_util.tree_leaves(replica))
    print(f"replica state captured: {n_leaves} leaves, "
          f"parent interruptions {snap.metrics.n_interruptions}, "
          f"out-of-service {snap.metrics.out_of_service_s*1e3:.2f} ms")
    # the replica can continue decoding from the fork point
    r_logits, _ = model.decode_step(
        jax.tree_util.tree_map(jnp.asarray, replica["params"]),
        jax.tree_util.tree_map(jnp.asarray, replica["cache"]),
        gen[4], jnp.full((B,), 20, jnp.int32),
    )
    print(f"replica decodes: logits {r_logits.shape}, finite "
          f"{bool(jnp.isfinite(r_logits).all())}")


if __name__ == "__main__":
    main()
