"""The paper's scenario end-to-end: a Redis-like JAX KV store serving an
open-loop query stream while BGSAVE snapshots fire, for all three fork
implementations. Prints the per-mode latency/interruption table
(paper Figs 4/5/9/10/11/20 in one run).

Run:  PYTHONPATH=src python examples/kvserve.py [--size-mb 128]
"""
import argparse

from repro.kvstore import KVEngine, KVStore, Workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size-mb", type=int, default=128)
    ap.add_argument("--qps", type=float, default=400)
    ap.add_argument("--duration", type=float, default=6.0)
    args = ap.parse_args()

    print(f"{'mode':10s} {'fork_ms':>8s} {'snap_p99':>9s} {'snap_max':>9s} "
          f"{'norm_p99':>9s} {'intr':>5s} {'oos_ms':>8s} {'min_tput':>8s}")
    for mode in ("blocking", "cow", "asyncfork"):
        store = KVStore(
            capacity=args.size_mb * (1 << 20) // (4 * 256),
            row_width=256, block_rows=256, seed=0,
        )
        eng = KVEngine(store, mode=mode, copier_threads=8,
                       persist_bandwidth=50e6, copier_duty=0.3 / 8)
        wl = Workload(rate_qps=args.qps, set_ratio=1.0, batch=16, seed=1)
        rep = eng.run(wl, duration_s=args.duration, bgsave_at=(0.15,))
        s = rep.summary()
        print(f"{mode:10s} {s['fork_ms']:8.2f} {s['snap_p99_ms']:9.2f} "
              f"{s['snap_max_ms']:9.2f} {s['normal_p99_ms']:9.2f} "
              f"{s['interruptions']:5.0f} {s['out_of_service_ms']:8.1f} "
              f"{s['min_tput_qps']:8.0f}")


if __name__ == "__main__":
    main()
