"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
periodic Async-fork checkpoints, then restore and verify.

The training loop DONATES (params, opt) every step — the pre-step buffers
die at every boundary. The checkpoint manager protects the fork-time state
exactly the way the paper's Async-fork protects the page table: O(metadata)
save, background copiers, non-donating steps only while the copy window is
open, progressive per-leaf release.

Run:  PYTHONPATH=src python examples/train_checkpoint.py [--steps 200]
"""
import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import TrainSnapshotManager, restore_checkpoint
from repro.configs import get_config
from repro.data.pipeline import SyntheticPipeline
from repro.configs.base import ShapeCfg
from repro.models import build_model
from repro.runtime.steps import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--mode", default="asyncfork", choices=["blocking", "asyncfork"])
    ap.add_argument("--out", default=None,  # default: outside the repo tree
                    help="checkpoint dir (default: $REPRO_CKPT_DIR or <tempdir>/repro_ckpts)")
    ap.add_argument("--shards", type=int, default=1,
                    help="partition each save across N parallel snapshot shards")
    args = ap.parse_args()

    # ~100M params: phi3-mini family at reduced width
    cfg = dataclasses.replace(
        get_config("phi3-mini-3.8b"),
        n_layers=8, d_model=768, n_heads=12, n_kv_heads=12,
        head_dim=64, d_ff=2048, vocab=8192,
    )
    model = build_model(cfg)
    params, opt = init_train_state(model, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))
    print(f"model: {n_params/1e6:.1f}M params "
          f"({sum(x.nbytes for x in jax.tree_util.tree_leaves(params))/1e6:.0f} MB "
          f"+ optimizer)")

    # batch sized for the single-core container; scale up on real hosts
    shape = ShapeCfg("local", seq_len=128, global_batch=4, kind="train")
    pipe = SyntheticPipeline(cfg, shape, seed=0)
    data = iter(pipe)

    fn = make_train_step(model, peak_lr=1e-3)
    donating = jax.jit(fn, donate_argnums=(0, 1))
    nondonating = jax.jit(fn)
    mgr = TrainSnapshotManager(args.out, mode=args.mode, copier_threads=4,
                               shards=args.shards)
    print(f"checkpointing to {mgr.directory} "
          f"({args.shards} shard{'s' if args.shards > 1 else ''})")

    losses, step_t, saved_steps = [], [], []
    for step in range(args.steps):
        batch = next(data)
        t0 = time.perf_counter()
        if step and step % args.save_every == 0:
            snap = mgr.save(step, params, opt)
            saved_steps.append(step)
            print(f"  step {step}: save() stalled "
                  f"{mgr.stall_log[-1][1]*1e3:.2f} ms ({args.mode})")
        step_fn = nondonating if mgr.snapshot_active() else donating
        params, opt, loss = step_fn(params, opt, batch)
        loss.block_until_ready()
        step_t.append(time.perf_counter() - t0)
        losses.append(float(loss))
        if step % 20 == 0:
            print(f"step {step:4d} loss {losses[-1]:.4f} "
                  f"({np.mean(step_t[-20:])*1e3:.0f} ms/step)")
    pipe.close()
    mgr.wait_all()
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f}); "
          f"p99 step {np.percentile(step_t, 99)*1e3:.0f} ms")

    if not saved_steps:
        print("no checkpoints taken (use --save-every < --steps); skipping restore")
        return
    # restore the last checkpoint THIS run wrote (the default directory is
    # shared and persistent, so listing it could pick up stale runs)
    last = f"step_{saved_steps[-1]:08d}"
    rparams, ropt = restore_checkpoint(os.path.join(mgr.directory, last))
    r_leaves = jax.tree_util.tree_leaves(rparams)
    print(f"restored {last}: {len(r_leaves)} param leaves, "
          f"opt step {int(np.asarray(ropt.step))}")
    # elastic restart: device_put with any mesh works because the
    # checkpoint stores full (unsharded) arrays
    restored_loss = model.loss(
        jax.tree_util.tree_map(jnp.asarray, rparams), next(iter(
            SyntheticPipeline(cfg, shape, seed=0)))
    )
    print(f"restored model loss {float(restored_loss):.4f} (finite: "
          f"{bool(jnp.isfinite(restored_loss))})")


if __name__ == "__main__":
    main()
